"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The tier-1 suite must collect and run on a bare interpreter (the seed died
at collection with ``ModuleNotFoundError: hypothesis``). When the real
package is available (see requirements-dev.txt) it is used untouched;
otherwise ``install()`` registers this shim under the ``hypothesis`` /
``hypothesis.strategies`` module names, providing the tiny subset the tests
use — ``@given`` with keyword strategies, ``@settings(max_examples=...,
deadline=...)``, ``st.integers(lo, hi)`` and ``st.sampled_from(seq)`` —
with deterministic example generation (fixed seeds, no shrinking).
"""
from __future__ import annotations

import functools
import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(0x5EED + 7919 * i)
                drawn = {name: s.draw(rng) for name, s in strategies.items()}
                fn(*args, **drawn, **kwargs)
        # hide the original signature: pytest must see () and not try to
        # resolve the strategy parameters as fixtures
        del wrapper.__wrapped__
        return wrapper
    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def install() -> None:
    """Register the shim as ``hypothesis`` + ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod

"""The repro.traces subsystem: the device (JAX threefry) backend must be
STATISTICALLY equivalent to the numpy reference oracle — same footprint
coverage, stride/stream structure, Zipf head/tail mass, gap-distribution
moments — for all 19 workloads; deterministic across processes for
threefry-derived seeds; and the executor's in-graph generation must be
bit-identical to pre-staged device traces, with ZERO host-side trace
generation on the steady-state path.

Tolerance policy (documented in docs/experiments.md): the backends share
model parameters but not RNG bit-streams, so per-trace statistics are
compared at T=4000 with the bounds asserted here, and end-to-end
*derived* figure metrics (IPC gains, relative latencies) must agree
within |log ratio| <= 0.10; raw second-order metrics (hit fractions,
prefetch counts) may move more and are not part of the policy.
"""
import hashlib
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.traces import (LINE, WORKLOAD_NAMES, WORKLOADS, footprint_bytes,
                          generate, get_backend, node_seed)
from repro.traces.device import generate_device, system_traces
from repro.traces.specs import PATTERN_IDS

T_STAT = 4000


def _pair(name, T=T_STAT, seed=0):
    return generate(name, T, seed), generate_device(name, T, seed)


# ---------------------------------------------------------------------------
# invariants shared by both backends (all 19 workloads)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", WORKLOAD_NAMES)
def test_bounds_alignment_and_dtypes(name):
    (ah, gh), (ad, gd) = _pair(name)
    for a, g in ((ah, gh), (ad, gd)):
        assert a.shape == (T_STAT,) and g.shape == (T_STAT,)
        assert a.dtype == np.int64 and g.dtype == np.float32
        assert (a >= 0).all() and (a < footprint_bytes(name)).all()
        assert (a % LINE == 0).all()
        assert (g > 0).all() and np.isfinite(g).all()


def test_footprint_coverage_parity():
    """Unique-line counts (footprint coverage at T events) must agree
    within 25 % for every workload — the patterns revisit lines at the
    same order of magnitude."""
    for name in WORKLOAD_NAMES:
        (ah, _), (ad, _) = _pair(name)
        uh, ud = len(np.unique(ah)), len(np.unique(ad))
        ratio = ud / max(uh, 1)
        assert 0.8 < ratio < 1.25, (name, uh, ud)


def test_gap_moments_parity():
    """Mean and std of the log-normal compute gaps within 10 / 20 %."""
    for name in WORKLOAD_NAMES:
        (_, gh), (_, gd) = _pair(name)
        assert 0.9 < gd.mean() / gh.mean() < 1.1, name
        assert 0.8 < gd.std() / gh.std() < 1.25, name


def test_stream_strided_structure():
    """Stream/strided traces touch nearly T distinct lines (each event
    advances one of a handful of streams) on both backends."""
    for name in WORKLOAD_NAMES:
        if WORKLOADS[name].pattern not in ("stream", "strided"):
            continue
        (ah, _), (ad, _) = _pair(name)
        for a in (ah, ad):
            assert len(np.unique(a)) > 0.95 * T_STAT, name


def test_tiled_locality():
    """Tiled traces stay inside a tile between consecutive events: the
    median line delta is far below the tile size on both backends."""
    for name in WORKLOAD_NAMES:
        spec = WORKLOADS[name]
        if spec.pattern != "tiled":
            continue
        (ah, _), (ad, _) = _pair(name)
        for a in (ah, ad):
            lines = a // LINE
            med = np.median(np.abs(np.diff(lines)))
            assert med <= spec.tile_lines, (name, med)


def test_zipf_head_and_tail_mass_parity():
    """For the skewed patterns (zipf + the random half of graph/mixed):
    the hottest-line shares — head mass — agree within 5 % absolute, and
    the singleton fraction — tail mass — within 10 % absolute."""
    for name in WORKLOAD_NAMES:
        if WORKLOADS[name].pattern not in ("zipf", "graph", "mixed"):
            continue
        (ah, _), (ad, _) = _pair(name)
        shares = []
        tails = []
        for a in (ah, ad):
            _, counts = np.unique(a, return_counts=True)
            counts = np.sort(counts)[::-1]
            shares.append(counts[:32].sum() / T_STAT)
            tails.append((counts == 1).sum() / T_STAT)
        assert abs(shares[0] - shares[1]) < 0.05, (name, shares)
        assert abs(tails[0] - tails[1]) < 0.10, (name, tails)


# ---------------------------------------------------------------------------
# property tests (hypothesis / shim)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(name=st.sampled_from(WORKLOAD_NAMES), seed=st.integers(0, 5),
       backend=st.sampled_from(["numpy", "device"]))
def test_property_bounds_alignment_determinism(name, seed, backend):
    """Both backends, any workload/seed: footprint bounds, line alignment,
    positive finite gaps, and call-to-call determinism (T fixed at 512 so
    the device path reuses one compiled kernel)."""
    b = get_backend(backend)
    a1, g1 = b.generate(name, 512, seed)
    a2, g2 = b.generate(name, 512, seed)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(g1, g2)
    assert (a1 >= 0).all() and (a1 < footprint_bytes(name)).all()
    assert (a1 % LINE == 0).all()
    assert (g1 > 0).all() and np.isfinite(g1).all()


@settings(max_examples=6, deadline=None)
@given(name=st.sampled_from(WORKLOAD_NAMES), seed=st.integers(0, 3))
def test_property_seeds_decorrelate(name, seed):
    """Different seeds produce different traces on both backends (the
    threefry key derivation must actually consume the seed)."""
    for b in (get_backend("numpy"), get_backend("device")):
        a1, _ = b.generate(name, 512, seed)
        a2, _ = b.generate(name, 512, seed + 1)
        assert not np.array_equal(a1, a2), (b.name, name)


# ---------------------------------------------------------------------------
# determinism across processes (threefry-derived seeds)
# ---------------------------------------------------------------------------

_DIGEST_SNIPPET = """
import hashlib, sys
sys.path.insert(0, {src!r})
from repro.traces.device import system_traces
a, g = system_traces(["bfs", "LU"], 1000, 3)
print(hashlib.sha256(a.tobytes() + g.tobytes()).hexdigest())
"""


def test_device_traces_identical_across_processes():
    """Device generation must be byte-identical across interpreters
    regardless of PYTHONHASHSEED (crc32 seeds + threefry keys — mirrors
    test_traces_repro.py for the numpy backend)."""
    a, g = system_traces(["bfs", "LU"], 1000, 3)
    here = hashlib.sha256(a.tobytes() + g.tobytes()).hexdigest()
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    digests = []
    for hashseed in ("0", "98765"):
        env = dict(os.environ, PYTHONHASHSEED=hashseed)
        out = subprocess.run(
            [sys.executable, "-c", _DIGEST_SNIPPET.format(src=src)],
            env=env, capture_output=True, text=True, check=True)
        digests.append(out.stdout.strip())
    assert digests[0] == digests[1] == here


# ---------------------------------------------------------------------------
# executor integration: no-host fast path + end-to-end tolerance
# ---------------------------------------------------------------------------

def test_executor_device_backend_zero_host_generation():
    """The device backend's steady-state path must generate no trace
    events on the host (the RunInfo counter the fig14 acceptance gate
    reads), record its backend, and skip the overlap pool entirely."""
    from repro.experiments import Experiment, workload_axis
    from repro.experiments import executor as _ex
    res = Experiment(name="nohost", T=600,
                     axes=(workload_axis(["LU", "bfs"]),)).run()
    assert res.info.trace_backend == "device"
    assert res.info.host_trace_events == 0
    d = res.info.as_dict()
    assert d["trace_backend"] == "device" and d["host_trace_events"] == 0
    # numpy comparison run on the same plan: the counter records events
    # actually GENERATED host-side (cold memo: 2 unique traces x 600;
    # a warm rerun generates nothing new)
    exp_np = Experiment(name="nohost", T=600, trace_backend="numpy",
                        axes=(workload_axis(["LU", "bfs"]),))
    _ex._TRACE_CACHE.clear()
    res_np = exp_np.run()
    assert res_np.info.host_trace_events == 2 * 600
    assert exp_np.run().info.host_trace_events == 0      # memoized reuse


def test_end_to_end_derived_metrics_within_tolerance():
    """The documented equivalence bar: per-figure DERIVED metrics (IPC
    gain and relative FAM latency of dram-prefetch over baseline) from
    the two backends agree within |log ratio| <= 0.10 at T=4000, per
    workload across the pattern classes."""
    from repro.core.famsim import SimFlags
    from repro.experiments import Experiment, execute, flag_axis, \
        workload_axis

    exp = Experiment(
        name="tol", T=T_STAT,
        axes=(workload_axis(["LU", "bfs", "mg", "canneal"]),
              flag_axis("variant", {
                  "base": SimFlags(core_prefetch=False, dram_prefetch=False),
                  "dram": SimFlags()})))
    plan = exp.plan()
    dev = execute(plan)
    ref = execute(plan, trace_backend="numpy")
    for w in ("LU", "bfs", "mg", "canneal"):
        for metric in ("ipc", "fam_latency"):
            rd = (np.mean(dev.get(workload=w, variant="dram")[metric]) /
                  np.mean(dev.get(workload=w, variant="base")[metric]))
            rn = (np.mean(ref.get(workload=w, variant="dram")[metric]) /
                  np.mean(ref.get(workload=w, variant="base")[metric]))
            assert abs(np.log(rd / rn)) <= 0.10, (w, metric, rd, rn)


def test_trace_gen_compare_record():
    """The fig14 engine-row acceptance record has the right shape. The
    ``device_not_slower`` VALUE is asserted only to be a bool: at this
    tiny T=1000 scale both host costs are single-digit milliseconds and
    the race is timing noise — the meaningful comparison is the fig14
    quick-scale record the CI artifact carries."""
    from benchmarks.common import trace_gen_compare
    from repro.experiments import Experiment, workload_axis
    plan = Experiment(name="cmp", T=1000,
                      axes=(workload_axis(["LU", "bfs"]),)).plan()
    rec = trace_gen_compare(plan)
    for field in ("numpy_host_gen_s", "device_host_stage_s",
                  "device_kernel_gen_s", "device_kernel_compile_s",
                  "host_speedup", "device_not_slower", "events_staged"):
        assert field in rec
    assert rec["events_staged"] == 2 * 1 * 1000   # S=2 is already canonical
    assert isinstance(rec["device_not_slower"], bool)
    assert rec["numpy_host_gen_s"] > 0 and rec["device_host_stage_s"] > 0


def test_pattern_ids_cover_all_workloads():
    """Every spec's pattern has a numeric id the device kernel selects
    on; the select groups (stream/strided), tiled, zipf, (graph/mixed)
    must partition the id space the kernel assumes."""
    assert PATTERN_IDS == {"stream": 0, "strided": 1, "tiled": 2,
                           "zipf": 3, "graph": 4, "mixed": 5}
    for spec in WORKLOADS.values():
        assert spec.pattern in PATTERN_IDS
        assert spec.tile_lines >= 64       # the device segment bound floor
        assert 1 <= spec.streams <= 8      # STREAMS_MAX one-hot width


def test_backend_registry():
    from repro.traces import BACKEND_NAMES, DEFAULT_BACKEND
    assert DEFAULT_BACKEND == "device" and set(BACKEND_NAMES) == \
        {"device", "numpy"}
    assert get_backend("numpy").name == "numpy"
    assert get_backend("device").name == "device"
    with pytest.raises(ValueError, match="unknown trace backend"):
        get_backend("cuda")
    # numpy backend's system_traces == the seed-derived generate calls
    a, _ = get_backend("numpy").system_traces(["LU", "bfs"], 400, 7)
    for i, w in enumerate(("LU", "bfs")):
        np.testing.assert_array_equal(a[i], generate(w, 400,
                                                     node_seed(7, i))[0])

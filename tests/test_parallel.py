"""Distribution-layer tests: sharding-rule fallback, gradient compression,
pipeline schedule (single-device axis), and checkpoint elasticity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.compat import make_mesh, shard_map
from repro.parallel.compression import (compress_decompress,
                                        ef_compress_allreduce, init_error)
from repro.parallel.sharding import ParallelContext, single_device_context


def test_spec_divisibility_fallback():
    ctx = single_device_context()
    # 1-sized axes: everything replicates cleanly
    spec = ctx.spec_for((8, 16), ("batch", "mlp"))
    assert all(e is None or e for e in spec)


def test_spec_prefers_first_fit():
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ParallelContext(mesh=mesh)
    # non-divisible dims fall back to replication, never error
    for shape, logical in [((7, 13), ("batch", "mlp")),
                           ((3,), ("q_heads",)),
                           ((5, 9, 11), ("layers", "batch", "kv_heads"))]:
        spec = ctx.spec_for(shape, logical)
        assert len(spec) == len(shape)


def test_compression_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    y = compress_decompress(x)
    err = np.abs(np.asarray(x - y))
    scale = np.abs(np.asarray(x)).max()
    assert err.max() <= scale / 127.0 + 1e-6


def test_error_feedback_accumulates_small_values():
    """EF must eventually transmit values far below one quantization step."""
    x = jnp.full((Q := 256,), 1e-4)
    err = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    for _ in range(50):
        q = compress_decompress(x + err)
        err = (x + err) - q
        total = total + q
    # after k steps, sum of transmitted ~ k * x
    np.testing.assert_allclose(np.asarray(total), 50 * 1e-4 *
                               np.ones(256), rtol=0.25)


def test_ef_allreduce_single_axis():
    mesh = make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P

    def f(g, e):
        return ef_compress_allreduce(g, e, "pod")

    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    e = jnp.zeros((64,))
    out, err = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), P()),
                                 out_specs=(P(), P())))(g, e)
    np.testing.assert_allclose(np.asarray(out + err), np.asarray(g),
                               atol=1e-6)


def test_checkpoint_elastic_roundtrip(tmp_path):
    from repro.checkpoint import Checkpointer
    state = {"a": jnp.arange(12.0).reshape(3, 4),
             "b": {"c": jnp.ones((5,), jnp.int32)}}
    ck = Checkpointer(str(tmp_path))
    ck.save(7, state, blocking=True)
    assert ck.latest_step() == 7
    restored = ck.restore(7, state)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(state["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(state["b"]["c"]))


def test_checkpoint_keep_gc(tmp_path):
    from repro.checkpoint import Checkpointer
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.ones(3)}, blocking=True)
    assert sorted(ck.all_steps()) == [3, 4]


def test_q8_adam_close_to_fp32():
    from repro.optim.adamw import (AdamWConfig, adamw_update,
                                   adamw_update_q8, init_opt_state,
                                   init_opt_state_q8)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 64))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (32, 64))}
    s32 = init_opt_state(params)
    sq8 = init_opt_state_q8(params)
    p32, s32, _ = adamw_update(cfg, grads, params, s32)
    pq8, sq8, _ = adamw_update_q8(cfg, grads, params, sq8)
    np.testing.assert_allclose(np.asarray(pq8["w"]), np.asarray(p32["w"]),
                               rtol=2e-2, atol=2e-3)

"""Expert-slab tiering: routed-expert reads through the tier == the pooled
weights; correlated routing raises the hit rate; pipeline module smoke."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FamConfig, fam_replace
from repro.serve.expert_tiering import ExpertTier

CFG = fam_replace(FamConfig(), cache_ways=4, prefetch_degree=4)


def make_tier(L=4, E=8, elems=32, fast=16):
    tier = ExpertTier(CFG, L, E, elems, fast, dtype=jnp.float32)
    slow = jax.random.normal(jax.random.PRNGKey(0), (L * E, elems),
                             jnp.float32)
    return tier, slow, tier.init(slow)


def test_expert_reads_match_pool():
    tier, slow, st = make_tier()
    rng = np.random.default_rng(0)
    for step in range(12):
        layer = jnp.int32(step % 4)
        experts = jnp.asarray(rng.choice(8, size=2, replace=False), jnp.int32)
        st, slabs = tier.gather_experts(st, slow, layer, experts)
        ids = np.asarray(tier.slab_ids(layer, experts))
        np.testing.assert_allclose(np.asarray(slabs), np.asarray(slow[ids]))


def test_correlated_routing_hits():
    """A skewed router (same hot experts every step) reaches a high hit rate
    after warmup — the expert-tier analogue of the paper's demand hits."""
    tier, slow, st = make_tier(L=2, E=16, fast=16)
    hot = jnp.asarray([3, 7], jnp.int32)
    for step in range(20):
        st, _ = tier.gather_experts(st, slow, jnp.int32(step % 2), hot)
    assert float(tier.pool.hit_rate(st)) > 0.8


def test_pipeline_forward_single_stage():
    """pipeline_forward with one stage == plain layer application."""
    from repro.parallel.compat import make_mesh
    from repro.parallel.pipeline import pipeline_forward
    mesh = make_mesh((1,), ("pod",), devices=jax.devices()[:1])

    def layer_fn(w, x):
        return jnp.tanh(x @ w)

    M, d = 3, 8
    w = jax.random.normal(jax.random.PRNGKey(0), (1, d, d))
    x = jax.random.normal(jax.random.PRNGKey(1), (M, 2, d))
    fn = pipeline_forward(lambda sp, xx: layer_fn(sp[0], xx), mesh, "pod",
                          num_stages=1, microbatches=M)
    out = jax.jit(fn)(w, x)
    ref = jnp.tanh(x @ w[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

"""The fused famsim cache-step kernel (repro.kernels.famsim_step).

Three contracts, all bit-exact:

* the fused Pallas kernel (interpret mode off-TPU) matches the pure-XLA
  reference op sequence on arbitrary driven op streams — random padded
  geometries, effective (num_sets, ways) below the padding, classic LRU
  and SRRIP replacement (hypothesis property test);
* an end-to-end simulation under ``kernel_backend="pallas"`` reproduces
  the default ``"xla"`` backend metric-for-metric;
* the backend is a STATIC compile tag: it splits planner compile groups,
  and unsupported policy/backend combinations fail loudly at build time.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import FamConfig, fam_replace
from repro.core import dram_cache as dc
from repro.core.famsim import SimFlags, _make_step, build_sim
from repro.core.traces import generate, node_seed
from repro.experiments import Experiment, config_axis, plan_points, \
    workload_axis
from repro.kernels.famsim_step import (FUSED_REPLACEMENT_MODES,
                                       KERNEL_BACKENDS, cache_step,
                                       cache_step_ref, fused_cache_step)
from repro.policies import PolicySet
from repro.policies.replacement import SRRIP

N, T = 2, 400
WL = ["LU", "bfs"]


def _node_traces(T=T):
    tr = [generate(w, T, node_seed(0, i)) for i, w in enumerate(WL)]
    return (jnp.asarray(np.stack([a for a, _ in tr])),
            jnp.asarray(np.stack([g for _, g in tr])))


# ---------------------------------------------------------------------------
# kernel vs reference: driven op streams over random padded geometries
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(pad_sets=st.sampled_from([4, 8, 16]),
       pad_ways=st.sampled_from([2, 4, 8]),
       sets_frac=st.floats(0.25, 1.0), ways_frac=st.floats(0.25, 1.0),
       srrip=st.booleans(), c=st.integers(1, 4), p=st.integers(1, 6),
       seed=st.integers(0, 2 ** 16))
def test_fused_cache_step_property(pad_sets, pad_ways, sets_frac, ways_frac,
                                   srrip, c, p, seed):
    """Fused kernel == reference, state and outputs, on every step of a
    random op stream — effective geometry strictly below the padding
    exercises the dynamic-ways mask and the modulo set hash."""
    eff_sets = max(1, int(pad_sets * sets_frac))
    eff_ways = max(1, int(pad_ways * ways_frac))
    policy = SRRIP.bind(None) if srrip else None
    rng = np.random.default_rng(seed)
    ref = fused = dc.init_cache(pad_sets, pad_ways)
    for _ in range(3):
        fills = jnp.asarray(rng.integers(0, 120, c), jnp.int32)
        fen = jnp.asarray(rng.random(c) < 0.7)
        demand = jnp.asarray(rng.integers(0, 120), jnp.int32)
        den = jnp.asarray(rng.random() < 0.8)
        probes = jnp.asarray(rng.integers(0, 120, p), jnp.int32)
        args = (fills, fen, demand, den, probes, eff_sets, eff_ways)
        ref, rhit, rprobes = cache_step_ref(ref, *args, policy=policy)
        fused, fhit, fprobes = cache_step(fused, *args, policy=policy,
                                          backend="pallas")
        np.testing.assert_array_equal(np.asarray(rhit), np.asarray(fhit))
        np.testing.assert_array_equal(np.asarray(rprobes),
                                      np.asarray(fprobes))
        for a, b in zip(ref, fused):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_cache_step_raw_wrapper_shapes():
    """The raw kernel wrapper's output contract: state arrays keep the
    padded shape, hit is a scalar bool, probe hits are (P,) bool."""
    cache = dc.init_cache(8, 4)
    tags, lru, stamp, hit, phits = fused_cache_step(
        cache.tags, cache.lru, cache.stamp,
        jnp.asarray([3, 9], jnp.int32), jnp.asarray([True, True]),
        jnp.asarray(3, jnp.int32), jnp.asarray(True),
        jnp.asarray([3, 5, 9], jnp.int32), 8, 4,
        mode="lru", max_rrpv=0, interpret=True)
    assert tags.shape == (8, 4) and lru.shape == (8, 4)
    assert stamp.shape == () and hit.shape == ()
    assert phits.shape == (3,) and phits.dtype == jnp.bool_
    assert bool(hit)                      # block 3 was just filled
    np.testing.assert_array_equal(np.asarray(phits), [True, False, True])


# ---------------------------------------------------------------------------
# end-to-end: pallas backend == xla backend, whole-sim bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("replacement", ["lru", "srrip"])
def test_sim_backends_bit_identical(replacement):
    addrs, gaps = _node_traces()
    ps = PolicySet(replacement=replacement)
    out = {}
    for backend in KERNEL_BACKENDS:
        cfg = fam_replace(FamConfig(), kernel_backend=backend)
        run = build_sim(cfg, SimFlags(), N, policies=ps)
        out[backend] = {k: np.asarray(v)
                        for k, v in run(addrs, gaps).items()}
    assert out["xla"].keys() == out["pallas"].keys()
    for k in out["xla"]:
        np.testing.assert_array_equal(out["xla"][k], out["pallas"][k],
                                      err_msg=k)


# ---------------------------------------------------------------------------
# static wiring: compile keys, build-time validation
# ---------------------------------------------------------------------------

def test_backend_is_a_static_compile_tag():
    """kernel_backend rides on geometry_free_shape(): the two backends
    select different traced programs, so the planner MUST split them —
    while same-backend points still fuse into one group."""
    exp = Experiment(
        name="kb", T=900,
        axes=(config_axis("backend", list(KERNEL_BACKENDS),
                          param="kernel_backend"),
              workload_axis(["LU", "bfs"])))
    plan = plan_points(exp.points())
    assert plan.num_groups == 2
    assert [len(g.indices) for g in plan.groups] == [2, 2]
    xla = FamConfig()
    pal = fam_replace(xla, kernel_backend="pallas")
    assert xla.geometry_free_shape() != pal.geometry_free_shape()


def test_unsupported_policy_fails_at_build_time():
    cfg = fam_replace(FamConfig(), kernel_backend="pallas")
    with pytest.raises(ValueError, match="kernel_backend='pallas'"):
        _make_step(cfg, N, policies=PolicySet(replacement="random"))
    # the supported modes are exactly the advertised ones
    assert FUSED_REPLACEMENT_MODES == ("lru", "srrip")
    # and lru/srrip build fine
    for repl in FUSED_REPLACEMENT_MODES:
        _make_step(cfg, N, policies=PolicySet(replacement=repl))


def test_unknown_backend_fails_at_build_time():
    cfg = fam_replace(FamConfig(), kernel_backend="cuda")
    with pytest.raises(ValueError, match="kernel_backend"):
        _make_step(cfg, N)

"""repro.search: the SearchSpace static/traced split must match the
planner's actual compile behavior, proposers must be deterministic
ask/tell machines whose state round-trips exactly, the loop must batch
each generation into one warm-after-gen-1 Experiment, and the trajectory
artifact must be byte-identical across processes under a fixed seed —
with resume-from-trajectory reproducing the remaining generations
exactly."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import FamConfig
from repro.experiments import grid_axis
from repro.policies import PolicySet, SimFlags
from repro.search import (Dimension, SearchSpace, categorical, cfg_field,
                          continuous, flag, get_proposer, integer,
                          load_best, log_continuous, policy_choice,
                          policy_param, read_trajectory, replay_best,
                          run_search, split_records)
from repro.search.proposers import available as proposers_available

# one shared tiny search configuration: every loop test below uses the
# SAME traced-only space / mixes / population / T, so the whole module
# compiles ONE group executable (first run pays it, the rest are warm)
MIXES = {"m1": ["LU", "bfs"], "m2": ["mg", "cc"]}
T = 900


def _space() -> SearchSpace:
    return SearchSpace((
        categorical("sched", policy_choice("scheduler"), ["fifo", "wfq"]),
        continuous("weight", policy_param("scheduler", "weight"), 0.5, 4.0),
        categorical("adapt", flag("bw_adapt"), [False, True]),
    ))


def _run(out_dir, **kw):
    kw.setdefault("proposer", "evolutionary")
    kw.setdefault("generations", 2)
    kw.setdefault("population", 3)
    return run_search(_space(), MIXES, T=T, seed=5, out_dir=out_dir, **kw)


# ---------------------------------------------------------------------------
# space
# ---------------------------------------------------------------------------

def test_dimension_sampling_types_and_bounds():
    rng = np.random.default_rng(0)
    c = continuous("c", policy_param("scheduler", "weight"), 0.5, 4.0)
    lc = log_continuous("l", policy_param("scheduler", "backlog_cap"),
                        500, 4000)
    i = integer("i", cfg_field("prefetch_degree"), 1, 4)
    cat = categorical("k", flag("bw_adapt"), [False, True])
    for _ in range(50):
        assert 0.5 <= c.sample(rng) <= 4.0
        assert 500 <= lc.sample(rng) <= 4000
        v = i.sample(rng)
        assert isinstance(v, int) and 1 <= v <= 4
        assert cat.sample(rng) in (False, True)
        # mutation stays in range; categorical mutation moves
        assert 0.5 <= c.mutate(2.0, rng) <= 4.0
        assert 500 <= lc.mutate(1000.0, rng) <= 4000
        assert 1 <= i.mutate(2, rng) <= 4
        assert cat.mutate(True, rng) is False
    # every sampled value is a JSON primitive (trajectory round-trip)
    s = _space().sample(rng)
    assert json.loads(json.dumps(s)) == s


def test_dimension_validation():
    with pytest.raises(ValueError, match="hi > lo"):
        continuous("x", policy_param("scheduler", "weight"), 2.0, 1.0)
    with pytest.raises(ValueError, match="log scale"):
        log_continuous("x", policy_param("scheduler", "weight"), 0.0, 1.0)
    with pytest.raises(ValueError, match=">= 2 choices"):
        categorical("x", flag("bw_adapt"), [True])
    with pytest.raises(ValueError, match="unknown policy kind"):
        policy_param("queueing", "weight")
    with pytest.raises(ValueError, match="no field"):
        cfg_field("nope")
    with pytest.raises(ValueError, match="no field"):
        flag("nope")
    with pytest.raises(ValueError, match="duplicate dimension names"):
        SearchSpace((categorical("a", flag("bw_adapt"), [False, True]),
                     categorical("a", flag("all_local"), [False, True])))


def test_split_static_vs_traced():
    """The classification feeding compile-aware mutation: policy params /
    flags / same-tag policy choices are traced; different-tag choices,
    shape fields, and up-sizing geometry are static."""
    base = FamConfig()
    sp = SearchSpace((
        categorical("chain", policy_choice("scheduler"), ["fifo", "wfq"]),
        continuous("w", policy_param("scheduler", "weight"), 0.5, 4.0),
        categorical("adapt", flag("bw_adapt"), [False, True]),
        integer("deg", cfg_field("prefetch_degree"), 1, 4),
        # down-sizing geometry stays inside the base padded allocation
        # (traced); up-sizing grows it and splits the executable (static)
        categorical("geom_dn", cfg_field("block_bytes"),
                    [base.block_bytes // 2, base.block_bytes]),
        categorical("geom_up", cfg_field("dram_cache_bytes"),
                    [base.dram_cache_bytes, 2 * base.dram_cache_bytes]),
    ))
    static, traced = sp.split(base)
    # fifo/wfq share the chain tag -> free; shape fields recompile
    assert set(static) == {"deg", "geom_up"}
    assert set(traced) == {"chain", "w", "adapt", "geom_dn"}
    s = sp.sample(np.random.default_rng(1))
    key = sp.static_key(s, base)
    assert [k for k, _ in key] == list(static)
    # a different-tag policy choice is a static (recompiling) move
    sp2 = SearchSpace((categorical("sched3", policy_choice("scheduler"),
                                   ["fifo", "strict"]),))
    assert sp2.split(base) == (("sched3",), ())
    # duplicate targets (two dims steering one knob) are rejected
    with pytest.raises(ValueError, match="duplicate dimension targets"):
        SearchSpace((
            integer("a", cfg_field("prefetch_degree"), 1, 4),
            integer("b", cfg_field("prefetch_degree"), 2, 8)))


def test_kernel_backend_dimension_is_static():
    """The cache-engine backend selects a different traced program
    (rides ``geometry_free_shape``), so a move along it must be priced
    as a recompile by the static/traced split."""
    sp = SearchSpace((categorical("kb", cfg_field("kernel_backend"),
                                  ["xla", "pallas"]),))
    assert sp.split(FamConfig()) == (("kb",), ())
    assert sp.static_key({"kb": "pallas"}) == (("kb", "pallas"),)


def test_axis_fields_choice_before_param_and_eager_validation():
    sp = SearchSpace((
        categorical("sched", policy_choice("scheduler"), ["fifo", "wfq"]),
        continuous("w", policy_param("scheduler", "weight"), 0.5, 4.0),
    ))
    f = sp.axis_fields({"sched": "wfq", "w": 1.5})
    assert f["policies"].scheduler == "wfq"
    assert dict(dict(f["policies"].overrides)["scheduler"])["weight"] == 1.5
    with pytest.raises(KeyError, match="missing dimensions"):
        sp.axis_fields({"sched": "wfq"})
    # a typo'd param dimension raises at mapping time (eager override
    # validation), listing the valid keys — never a silent no-op knob
    bad = SearchSpace((
        continuous("w", policy_param("scheduler", "wieght"), 0.5, 4.0),))
    with pytest.raises(ValueError, match="valid params.*weight"):
        bad.axis_fields({"w": 1.0})


def test_grid_axis_from_dicts():
    ax = grid_axis("candidate", {
        "a": {"cfg": {"prefetch_degree": 2}, "policies": PolicySet()},
        "b": {"flags": SimFlags(bw_adapt=True)},
    })
    assert ax.values[0].cfg == (("prefetch_degree", 2),)
    assert ax.values[1].flags.bw_adapt
    with pytest.raises(ValueError, match="unknown AxisValue fields"):
        grid_axis("x", {"a": {"cfgg": {}}})
    with pytest.raises(ValueError, match="no field"):
        grid_axis("x", {"a": {"cfg": {"nope": 1}}})


# ---------------------------------------------------------------------------
# proposers
# ---------------------------------------------------------------------------

def _synthetic_fitness(s):
    # optimum: wfq with weight 3.0, adapt on
    return (-(s["weight"] - 3.0) ** 2
            - (0.0 if s["sched"] == "wfq" else 0.5)
            - (0.0 if s["adapt"] else 0.25))


def test_proposer_registry():
    assert set(proposers_available()) >= {"random", "evolutionary",
                                          "halving"}
    with pytest.raises(KeyError, match="no proposer named"):
        get_proposer("annealing")


def test_evolutionary_improves_and_state_round_trips():
    sp = _space()
    p = get_proposer("evolutionary")(sp, np.random.default_rng(3), 8)
    firsts, bests = None, None
    for _ in range(8):
        samples = p.ask()
        fits = [_synthetic_fitness(s) for s in samples]
        if firsts is None:
            firsts = max(fits)
        bests = max(bests, max(fits)) if bests is not None else max(fits)
        p.tell(samples, fits)
    assert bests > firsts
    top = p.parents[0][0]
    assert top["sched"] == "wfq" and abs(top["weight"] - 3.0) < 0.5
    # state + rng round-trip (through JSON, like the trajectory does)
    # => identical continuation
    state = json.loads(json.dumps(p.state()))
    q = get_proposer("evolutionary")(sp, np.random.default_rng(0), 8)
    q.load_state(state)
    shared = np.random.default_rng(99).bit_generator.state
    p.rng.bit_generator.state = shared
    q.rng.bit_generator.state = shared
    assert p.ask() == q.ask()


def test_halving_schedule():
    sp = _space()
    p = get_proposer("halving")(sp, np.random.default_rng(1), 2,
                                rungs=3, eta=2, min_T=512)
    T_full = 8000
    widths, Ts = [], []
    for _ in range(4):                      # one full bracket + restart
        samples = p.ask()
        widths.append(len(samples))
        Ts.append(p.round_T(T_full))
        p.tell(samples, [_synthetic_fitness(s) for s in samples])
    assert widths == [8, 4, 2, 8]           # wide screen -> promote -> restart
    assert Ts == [2000, 4000, 8000, 2000]
    assert p.round_T(600) == 512            # clamp floor


def test_random_proposer_is_memoryless_and_seeded():
    sp = _space()
    a = get_proposer("random")(sp, np.random.default_rng(7), 4)
    b = get_proposer("random")(sp, np.random.default_rng(7), 4)
    a.tell([], [])                          # no-op by contract
    assert a.ask() == b.ask()


# ---------------------------------------------------------------------------
# the loop (shared compile: same space/mixes/population/T everywhere)
# ---------------------------------------------------------------------------

def test_search_loop_end_to_end(tmp_path):
    """Two generations over a traced-only space: generation 2 re-lands on
    generation 1's executable (zero new group keys, zero XLA compiles),
    the trajectory parses into header/candidates/generations, and the
    winner replays through plain repro.experiments byte-identically."""
    out = _run(tmp_path / "s")
    assert out["generations_run"] == 2
    t1, t2 = out["timings"]
    assert t1["new_group_keys"] == 1 and t2["new_group_keys"] == 0
    assert t2["xla_compiles"] == 0          # the warm-generation promise
    assert t2["groups_reused"] == t2["planned_groups"]
    header, cands, gens = split_records(
        read_trajectory(out["trajectory"]))
    assert header["space"] == _space().describe()
    assert len(gens) == 2 and len(cands) == 6
    assert all(not c["warm"] for c in cands if c["gen"] == 1)
    assert all(c["warm"] for c in cands if c["gen"] == 2)
    # baseline normalization: objectives are uplifts (baseline == 1.0)
    best = load_best(out["best_path"])
    assert best["objective"] == out["best"]["objective"]
    replay = replay_best(best)
    assert replay["matches"], replay


def test_trajectory_byte_identical_across_processes(tmp_path):
    """Same seed => byte-identical trajectory/best.json in fresh
    interpreters with DIFFERENT hash randomization (the same pattern as
    the threefry trace-seed test) — wall clock and runtime cache state
    live in the timings sidecar, never in the contract files."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    snippet = (
        "import sys; sys.path[:0] = [{src!r}]\n"
        "from repro.search import run_search, SearchSpace, categorical, "
        "continuous, policy_choice, policy_param, flag\n"
        "sp = SearchSpace(("
        "categorical('sched', policy_choice('scheduler'), ['fifo','wfq']),"
        "continuous('weight', policy_param('scheduler','weight'), .5, 4.),"
        "categorical('adapt', flag('bw_adapt'), [False, True])))\n"
        "run_search(sp, {{'m1': ['LU', 'bfs']}}, proposer='random', "
        "generations=2, population=2, T=600, seed=11, out_dir={out!r})\n"
    )
    blobs = {}
    for hashseed in ("0", "1"):
        out = tmp_path / f"h{hashseed}"
        env = dict(os.environ, PYTHONHASHSEED=hashseed)
        subprocess.run(
            [sys.executable, "-c",
             snippet.format(src=os.path.join(root, "src"), out=str(out))],
            check=True, env=env, capture_output=True, text=True)
        blobs[hashseed] = ((out / "trajectory.jsonl").read_bytes(),
                           (out / "best.json").read_bytes())
    assert blobs["0"] == blobs["1"]


def test_resume_reproduces_remaining_generations(tmp_path):
    """gens=3 in one shot vs gens=2 + resume-to-3: every record after the
    header (candidates, generation states, best.json) must be identical —
    the RNG/proposer state round-trip and the plan-level warm-key rebuild
    are exact."""
    full = _run(tmp_path / "full", generations=3)
    part = _run(tmp_path / "part", generations=2)
    resumed = _run(tmp_path / "part", generations=3, resume=True)
    lines_full = (tmp_path / "full/trajectory.jsonl").read_text().splitlines()
    lines_part = (tmp_path / "part/trajectory.jsonl").read_text().splitlines()
    # headers differ only in the generations target
    h_full, h_part = json.loads(lines_full[0]), json.loads(lines_part[0])
    assert h_part.pop("generations") == 2 and h_full.pop("generations") == 3
    assert h_full == h_part
    assert lines_full[1:] == lines_part[1:]
    assert resumed["generations_run"] == 1 and part["generations_run"] == 2
    assert (tmp_path / "full/best.json").read_bytes() == \
        (tmp_path / "part/best.json").read_bytes()
    assert resumed["best"] == full["best"]
    # resuming with a different space fingerprint must refuse
    other = SearchSpace((
        categorical("sched", policy_choice("scheduler"), ["fifo", "wfq"]),))
    with pytest.raises(ValueError, match="resume mismatch"):
        run_search(other, MIXES, T=T, seed=5, generations=4,
                   out_dir=tmp_path / "part", resume=True)
